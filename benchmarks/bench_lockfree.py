"""Paper reproduction: lock-based vs lock-free FIFO exchange (Tables 2,
Figures 7/8 of Harper & de Gooijer 2014).

Test matrix (mirrors §6):
  impl        lock-based (mutex deque)  vs  lock-free (NBB SPSC ring)
  payload     scalar (8 B int) | message (24 B) | packet (256 B)
  deployment  single-core (both threads pinned to one CPU)
              multicore   (producer/consumer pinned to different CPUs)
              no-affinity (scheduler decides)

One producer thread sends N messages with transaction IDs 1..N; one
consumer receives and verifies FIFO order (exactly the paper's stress
design, §4).  Metrics: throughput (msgs/s) and one-way latency
percentiles (timestamp at insert -> read).

Derived outputs:
  * multicore penalty  = multicore / single-core throughput, lock-based
    (paper Table 2: 0.2-0.8x)
  * lock-free speedup  = lock-free / lock-based throughput per cell
    (paper Figure 8: 2-25x)
  * packet-mode speedup = K-item burst / scalar exchange throughput per
    impl (paper Tables 5-7: amortizing the per-exchange overhead)

CPython's GIL means these host threads interleave rather than truly
overlap; the paper's *mechanism* — mutex handoff + convoying between
cores is expensive; counter-synchronized slot-disjoint rings are not —
is exactly what the GIL amplifies, so the qualitative ordering matches
the paper and the quantitative numbers are recorded as measured.
"""
from __future__ import annotations

import os
import statistics
import threading
import time
from typing import Dict, List

from repro.core.host_queue import LockedQueue, SpscQueue
from repro.core import nbb

PAYLOADS = {
    "scalar": lambda i: i,
    "message": lambda i: (i, b"m" * 16),        # ~24 B like the paper
    "packet": lambda i: (i, b"p" * 248),
}


def _pin(cpu: int | None) -> None:
    if cpu is not None and hasattr(os, "sched_setaffinity"):
        try:
            os.sched_setaffinity(0, {cpu % os.cpu_count()})
        except OSError:
            pass


def _run_exchange(queue, payload_fn, n_msgs: int, cpu_prod, cpu_cons,
                  sample_every: int = 64) -> Dict:
    """One producer -> one consumer through ``queue``; FIFO-verified."""
    lat: List[float] = []
    t_start = [0.0]
    t_end = [0.0]
    err: List[str] = []

    def producer():
        _pin(cpu_prod)
        t_start[0] = time.perf_counter()
        for i in range(1, n_msgs + 1):
            stamp = time.perf_counter() if i % sample_every == 0 else 0.0
            item = (stamp, payload_fn(i))
            if isinstance(queue, LockedQueue):
                queue.put(item)          # blocking variant parks on futex
            else:
                while queue.insert_item(item) != nbb.OK:
                    time.sleep(0)        # Table-1: yield and retry

    def consumer():
        _pin(cpu_cons)
        expect = 1
        for _ in range(n_msgs):
            item = queue.get()
            now = time.perf_counter()
            stamp, data = item
            tid = data if isinstance(data, int) else data[0]
            if tid != expect:
                err.append(f"FIFO violation: got {tid}, want {expect}")
                break
            expect += 1
            if stamp:
                lat.append(now - stamp)
        t_end[0] = time.perf_counter()

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tc.start(); tp.start()
    tp.join(); tc.join()
    assert not err, err[0]
    dt = t_end[0] - t_start[0]
    lat_us = sorted(x * 1e6 for x in lat)
    return {
        "msgs_per_s": n_msgs / dt,
        "lat_us_p50": lat_us[len(lat_us) // 2] if lat_us else float("nan"),
        "lat_us_mean": statistics.fmean(lat_us) if lat_us else float("nan"),
    }


def run(n_msgs: int = 50_000, capacity: int = 256) -> List[Dict]:
    ncpu = os.cpu_count() or 1
    deployments = {
        "single_core": (0, 0),
        "multicore": (0, 1 % ncpu),
        "no_affinity": (None, None),
    }
    rows = []
    for impl in ("lock_blocking", "lock_based", "lock_free"):
        for pname, pfn in PAYLOADS.items():
            for dname, (cp, cc) in deployments.items():
                if impl == "lock_blocking":
                    q = LockedQueue(capacity, blocking=True)
                elif impl == "lock_based":
                    q = LockedQueue(capacity)
                else:
                    q = SpscQueue(capacity)
                r = _run_exchange(q, pfn, n_msgs, cp, cc)
                rows.append({"impl": impl, "payload": pname,
                             "deployment": dname, **r})
    return rows


def derive(rows: List[Dict]) -> Dict:
    """Paper Table-2 multicore penalty + Figure-8 lock-free speedups."""
    def get(impl, payload, dep):
        return next(r for r in rows if r["impl"] == impl
                    and r["payload"] == payload and r["deployment"] == dep)

    out = {"multicore_penalty_lock_based": {},
           "multicore_penalty_lock_blocking": {},
           "lockfree_speedup_multicore": {},
           "lockfree_speedup_vs_blocking_multicore": {},
           "lockfree_speedup_single": {},
           "lockfree_latency_speedup_multicore": {}}
    for p in PAYLOADS:
        lb1 = get("lock_based", p, "single_core")
        lbm = get("lock_based", p, "multicore")
        bb1 = get("lock_blocking", p, "single_core")
        bbm = get("lock_blocking", p, "multicore")
        lf1 = get("lock_free", p, "single_core")
        lfm = get("lock_free", p, "multicore")
        out["multicore_penalty_lock_based"][p] = (
            lbm["msgs_per_s"] / lb1["msgs_per_s"])
        out["multicore_penalty_lock_blocking"][p] = (
            bbm["msgs_per_s"] / bb1["msgs_per_s"])
        out["lockfree_speedup_multicore"][p] = (
            lfm["msgs_per_s"] / lbm["msgs_per_s"])
        out["lockfree_speedup_vs_blocking_multicore"][p] = (
            lfm["msgs_per_s"] / bbm["msgs_per_s"])
        out["lockfree_speedup_single"][p] = (
            lf1["msgs_per_s"] / lb1["msgs_per_s"])
        out["lockfree_latency_speedup_multicore"][p] = (
            bbm["lat_us_mean"] / lfm["lat_us_mean"])
    return out


def burst_vs_scalar(n_msgs: int = 50_000, capacity: int = 256,
                    burst_sizes=(1, 4, 16, 64)) -> List[Dict]:
    """Packet-mode vs scalar-mode exchange (paper Tables 5-7): the same
    n_msgs ints cross one producer->consumer ring either one at a time
    (burst=1: one counter pair + one slot write per item) or in K-item
    bursts (one counter pair + two slice copies per K items).  Run for
    both the lock-free NBB ring and the mutex baseline — amortization
    helps both, but only the NBB keeps the exchange wait-free."""
    rows = []
    for impl in ("lock_based", "lock_free"):
        for k in burst_sizes:
            q = LockedQueue(capacity) if impl == "lock_based" else SpscQueue(capacity)
            got = [0]
            err: List[str] = []
            failed = threading.Event()  # consumer error -> producer exits

            def producer():
                i = 0
                while i < n_msgs and not failed.is_set():
                    vals = list(range(i, min(i + k, n_msgs)))
                    while vals and not failed.is_set():
                        _, n = q.send_burst(vals)
                        if n:
                            vals = vals[n:]
                        else:
                            time.sleep(0)       # Table 1: yield on FULL
                    i += k

            def consumer():
                expect = 0
                while expect < n_msgs:
                    block = q.drain_burst()
                    if not block:
                        time.sleep(0)
                        continue
                    for v in block:
                        if v != expect:
                            err.append(f"FIFO violation {v} != {expect}")
                            failed.set()
                            return
                        expect += 1
                got[0] = expect

            # daemon + bounded join: a FIFO regression must surface as
            # the assert below, not as a producer spinning on a full
            # ring forever after the consumer bails out.
            tp = threading.Thread(target=producer, daemon=True)
            tc = threading.Thread(target=consumer, daemon=True)
            t0 = time.perf_counter()
            tc.start(); tp.start()
            tp.join(timeout=120); tc.join(timeout=120)
            dt = time.perf_counter() - t0
            assert not err, err[0]
            assert not (tp.is_alive() or tc.is_alive()), "burst bench hung"
            assert got[0] == n_msgs
            rows.append({"impl": impl, "burst": k,
                         "msgs_per_s": n_msgs / dt})
    for impl in ("lock_based", "lock_free"):
        base = next(r for r in rows
                    if r["impl"] == impl and r["burst"] == 1)
        for r in rows:
            if r["impl"] == impl:
                r["speedup_vs_scalar"] = r["msgs_per_s"] / base["msgs_per_s"]
    return rows


def state_vs_fifo(n_msgs: int = 50_000) -> Dict:
    """The paper's §7 prediction: state-message policy (NBW, drops the
    FIFO requirement) should out-run the FIFO NBB.  One writer thread
    publishes n values; one reader polls for fresh versions until it has
    seen the final value.  Writer-side throughput is the comparison —
    the NBW writer never blocks or backs off."""
    from repro.core.channels import ChannelType, Domain

    dom = Domain(lock_free=True)
    results = {}
    for port, ctype in enumerate((ChannelType.MESSAGE, ChannelType.STATE)):
        a = dom.create_endpoint(0, 10 + port)
        b = dom.create_endpoint(1, 20 + port)
        ch = dom.connect(ctype, a, b)
        done = threading.Event()

        def writer():
            for i in range(1, n_msgs + 1):
                while ch.send(i) != 0:     # STATE never loops here
                    time.sleep(0)
            done.set()

        seen = [0]

        def reader():
            while not (done.is_set() and seen[0] == n_msgs):
                status, v = ch.recv()
                if status == 0 and v is not None:
                    seen[0] = max(seen[0], v)
                    if seen[0] == n_msgs:
                        return
                else:
                    time.sleep(0)

        tw = threading.Thread(target=writer)
        tr = threading.Thread(target=reader)
        t0 = time.perf_counter()
        tr.start(); tw.start()
        tw.join(); tr.join(timeout=30)
        dt = time.perf_counter() - t0
        results[ctype.value] = n_msgs / dt
    results["state_speedup"] = results["state"] / results["message"]
    return results


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small message counts for CI smoke")
    ap.add_argument("--n-msgs", type=int, default=None)
    args = ap.parse_args(argv)
    n_msgs = args.n_msgs or (2_000 if args.quick else 50_000)

    rows = run(n_msgs=n_msgs)
    print("impl,payload,deployment,msgs_per_s,lat_us_p50,lat_us_mean")
    for r in rows:
        print(f"{r['impl']},{r['payload']},{r['deployment']},"
              f"{r['msgs_per_s']:.0f},{r['lat_us_p50']:.2f},"
              f"{r['lat_us_mean']:.2f}")
    d = derive(rows)
    print("\n# derived (paper Table 2 / Fig 8 analogues)")
    for k, v in d.items():
        for p, x in v.items():
            print(f"{k},{p},{x:.2f}")
    bv = burst_vs_scalar(n_msgs=n_msgs)
    print("\n# packet vs scalar exchange (paper Tables 5-7 analogue)")
    print("impl,burst,msgs_per_s,speedup_vs_scalar")
    for r in bv:
        print(f"{r['impl']},{r['burst']},{r['msgs_per_s']:.0f},"
              f"{r['speedup_vs_scalar']:.2f}")
    sv = state_vs_fifo(n_msgs=n_msgs)
    print("\n# paper §7 prediction: state (NBW) vs FIFO (NBB) policy")
    print(f"fifo_msgs_per_s,{sv['message']:.0f}")
    print(f"state_writes_per_s,{sv['state']:.0f}")
    print(f"state_policy_speedup,{sv['state_speedup']:.2f}")
    return rows, d


if __name__ == "__main__":
    main()
