"""Deterministic interleaving checker benchmark (DESIGN.md §15).

Measures the model-checking layer itself and re-asserts its core
guarantees as deterministic gates:

- **Exhaustive coverage**: every scenario tagged ``expect="pass"`` is
  explored at its full budget; the ones that exhaust are complete
  proofs over their bounded casts, and any counterexample fails the
  bench with the minimized replay schedule printed (the one-line repro
  IS the bug report).
- **Detector sensitivity**: the two preserved-broken scenarios
  (``legacy_statecell_compaction``, ``broken_ring``) must still be
  convicted — a checker that stops finding planted bugs is broken.
- **Throughput**: schedules/second for the DFS explorer and the seeded
  fuzzer (re-execution rate is THE cost driver of stateless model
  checking).
- **Zero-overhead unarmed**: a hot loop over the instrumented
  primitives with no scheduler armed must take ZERO yield points
  (``interleave.ARMED_HITS`` unchanged — the paper's packaging claim
  says instrumentation may not tax the fast path), plus a relative
  wall-clock comparison against the pre-instrumentation ceiling.

Usage:  PYTHONPATH=src python benchmarks/bench_check.py [--quick]
Emits:  BENCH_check.json (cwd)
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import interleave as il
from repro.core.nbb import HostNBB
from repro.checker import scenarios


def run_explores(quick: bool) -> tuple:
    """Explore every registered scenario; returns (records, failures)."""
    records, failures = [], []
    for name, scen in sorted(scenarios.SCENARIOS.items()):
        budget = scen.explore_budget
        if quick:
            budget = min(budget, 1500)
        t0 = time.perf_counter()
        r = scenarios.explore_scenario(name, max_executions=budget)
        dt = time.perf_counter() - t0
        rec = {
            "scenario": name,
            "structure": scen.structure,
            "expect": scen.expect,
            "executions": r.executions,
            "distinct_states": r.distinct_states,
            "exhausted": r.exhausted,
            "max_trace_len": r.max_trace_len,
            "seconds": round(dt, 3),
            "schedules_per_sec": round(r.executions / dt, 1) if dt else 0.0,
            "ok": r.ok,
        }
        if scen.expect == "pass" and not r.ok:
            cx = r.counterexample
            mini = il.minimize(scen.make_world,
                               il.run_schedule(scen.make_world, cx.schedule,
                                               max_steps=scen.max_steps,
                                               strict=False),
                               max_steps=scen.max_steps)
            rec["counterexample"] = {
                "error": cx.error, "schedule": list(mini)}
            failures.append(
                f"{name}: {cx.error_type}\n"
                f"  minimized replay schedule: {list(mini)}\n"
                f"  repro: interleave.run_schedule("
                f"scenarios.get({name!r}).make_world, {list(mini)})")
        elif scen.expect == "violation" and r.ok:
            failures.append(
                f"{name}: expected a violation (detector sensitivity "
                f"check) but exploration found none in {r.executions} "
                f"executions")
        records.append(rec)
        status = "ok" if (r.ok == (scen.expect == "pass")) else "FAIL"
        print(f"  {name:32s} exec={r.executions:6d} "
              f"distinct={r.distinct_states:6d} "
              f"exhausted={str(r.exhausted):5s} "
              f"{rec['schedules_per_sec']:8.1f} sched/s  [{status}]")
    return records, failures


def run_fuzz(quick: bool) -> dict:
    """Fuzzer throughput + clean-pass gate on two large scenarios."""
    runs = 40 if quick else 300
    out = {}
    for name in ("mpsc_fanin", "torn_span_recovery"):
        t0 = time.perf_counter()
        f = scenarios.fuzz_scenario(name, seed=0, runs=runs)
        dt = time.perf_counter() - t0
        assert f.ok, (f"fuzz found a bug in {name}: "
                      f"{f.counterexample.error}\n"
                      f"repro: {f.counterexample.repro(name)}")
        out[name] = {"runs": f.runs, "seconds": round(dt, 3),
                     "schedules_per_sec": round(f.runs / dt, 1)}
    return out


def run_unarmed_overhead(quick: bool) -> dict:
    """The zero-overhead-unarmed gate: no hits, and the wall-clock of
    the instrumented hot path (scalar + burst ring ops)."""
    n = 20_000 if quick else 200_000
    ring = HostNBB(64)
    assert il._active is None
    hits_before = il.ARMED_HITS
    t0 = time.perf_counter()
    for i in range(n):
        ring.insert_item(i)
        ring.read_item()
    scalar_dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    burst = list(range(32))
    for _ in range(n // 32):
        ring.send_burst(burst)
        ring.drain_burst(32)
    burst_dt = time.perf_counter() - t0
    added_ops = il.ARMED_HITS - hits_before
    assert added_ops == 0, (
        f"unarmed hot path took {added_ops} yield points — the "
        f"zero-overhead-unarmed guarantee is broken")
    return {
        "ops": n,
        "armed_hits_delta": added_ops,
        "scalar_ns_per_op": round(scalar_dt / (2 * n) * 1e9, 1),
        "burst_ns_per_item": round(burst_dt / (2 * (n // 32) * 32) * 1e9,
                                   1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="capped budgets for CI smoke")
    args = ap.parse_args()

    print("== deterministic interleaving checker bench "
          f"({'quick' if args.quick else 'full'}) ==")
    print("-- exhaustive exploration --")
    t0 = time.perf_counter()
    explore_recs, failures = run_explores(args.quick)
    print("-- seeded fuzzing --")
    fuzz_recs = run_fuzz(args.quick)
    for name, rec in fuzz_recs.items():
        print(f"  {name:32s} runs={rec['runs']:6d} "
              f"{rec['schedules_per_sec']:8.1f} sched/s")
    print("-- zero-overhead unarmed --")
    overhead = run_unarmed_overhead(args.quick)
    print(f"  armed_hits_delta={overhead['armed_hits_delta']} "
          f"scalar={overhead['scalar_ns_per_op']}ns/op "
          f"burst={overhead['burst_ns_per_item']}ns/item")

    total = time.perf_counter() - t0
    exhausted = sum(1 for r in explore_recs
                    if r["exhausted"] and r["expect"] == "pass")
    result = {
        "bench": "check",
        "mode": "quick" if args.quick else "full",
        "total_seconds": round(total, 2),
        "scenarios": explore_recs,
        "scenarios_exhausted": exhausted,
        "interleavings_covered": sum(r["executions"]
                                     for r in explore_recs),
        "distinct_states": sum(r["distinct_states"]
                               for r in explore_recs),
        "fuzz": fuzz_recs,
        "unarmed_overhead": overhead,
        "ok": not failures,
    }
    with open("BENCH_check.json", "w") as f:
        json.dump(result, f, indent=1)
        f.write("\n")
    print(f"== {result['interleavings_covered']} interleavings, "
          f"{result['distinct_states']} distinct states, "
          f"{exhausted} scenarios exhausted, {total:.1f}s ==")
    if failures:
        print("== FAILURES ==")
        for msg in failures:
            print(msg)
        raise SystemExit(1)
    print("OK — wrote BENCH_check.json")


if __name__ == "__main__":
    main()
