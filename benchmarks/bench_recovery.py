"""Crash-recovery benchmark: what a checkpoint costs and what a restart
buys back (DESIGN.md §14).

Measures, on the slot_paged engine mid-decode:

- **snapshot latency** — capture (one host sync gathering every written
  KV page) and write (checksum + fsync + atomic rename), separately;
- **snapshot size** — bytes on disk vs the resident KV bytes it images
  (pages are stored once however many block tables share them, and
  reserved-ahead pages are recorded but not copied, so the ratio < 1 is
  the structural-sharing win);
- **restore-to-first-token** — from ``restore_latest()`` on a fresh
  engine to the first post-restart harvested token reaching a client
  (the metric an operator actually waits on);
- **journal replay** — how many requests (and decoded tokens) the WAL
  re-creates that the snapshot alone would have lost.

Asserted, not just measured: every resumed stream is byte-identical to
the uninterrupted reference run — recovery must never cost correctness
to buy availability.

Usage:  PYTHONPATH=src python benchmarks/bench_recovery.py [--quick]
Emits:  BENCH_recovery.json (cwd)
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from typing import Dict, List, Optional

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.serve import snapshot as snapshot_mod  # noqa: E402

MAX_TICKS = 3000


def _mk_engine(model, params, n_requests: int,
               snapshot_dir: Optional[str] = None):
    from repro.serve.engine import ServeEngine

    return ServeEngine(model, params, max_batch=4, max_len=128,
                       n_clients=2, pool_pages=48, page_size=8,
                       intake_depth=n_requests + 8,
                       scheduler="slot_paged", chunk_tokens=16, k_max=4,
                       snapshot_dir=snapshot_dir)


def _share_jit(eng, donor) -> None:
    eng._jit_loops = donor._jit_loops
    eng._jit_chunked = donor._jit_chunked
    eng._jit_prefill = donor._jit_prefill
    eng._jit_decode = donor._jit_decode
    eng._jit_write_slot = donor._jit_write_slot
    eng.pool._cow_fns = donor.pool._cow_fns
    eng.pool._swap_fns = donor.pool._swap_fns


def make_workload(n_requests: int, vocab: int, max_tokens: int,
                  seed: int = 0) -> List[Dict]:
    rng = np.random.default_rng(seed)
    return [{"prompt": rng.integers(0, 1000, 10) % vocab,
             "max_tokens": max_tokens} for _ in range(n_requests)]


def _submit(sessions, workload):
    return [sessions[i % len(sessions)].submit_i(
                w["prompt"], max_tokens=w["max_tokens"])
            for i, w in enumerate(workload)]


def _drive(eng, handles) -> int:
    ticks = 0
    while not all(h.test() for h in handles):
        ticks += 1
        assert ticks < MAX_TICKS, "engine wedged"
        eng.tick()
    return ticks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-tokens", type=int, default=None)
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.models.model import build_model

    n_requests = args.requests or (6 if args.quick else 16)
    max_tokens = args.max_tokens or (24 if args.quick else 48)
    n_late = 2      # submitted after the last snapshot: WAL-only recovery
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = make_workload(n_requests + n_late, cfg.vocab_size,
                             max_tokens)

    # Reference: the uninterrupted run (also the jit donor).
    ref_eng = _mk_engine(model, params, n_requests)
    ref_sessions = [ref_eng.connect(c) for c in range(2)]
    ref_handles = _submit(ref_sessions, workload)
    ref_ticks = _drive(ref_eng, ref_handles)
    ref_tokens = [list(map(int, h.response.tokens_out))
                  for h in ref_handles]

    snap_dir = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        kill_at = max(2, ref_ticks // 2)
        eng = _mk_engine(model, params, n_requests, snapshot_dir=snap_dir)
        _share_jit(eng, ref_eng)
        sessions = [eng.connect(c) for c in range(2)]
        handles = _submit(sessions, workload[:n_requests])
        for _ in range(kill_at):
            eng.tick()

        # Snapshot cost, capture vs write split.  Warm pass first so the
        # gather trace is compiled out of the measured numbers.
        eng.snapshot()
        t0 = time.perf_counter()
        snap = eng.snapshot()
        t_capture = time.perf_counter() - t0
        t0 = time.perf_counter()
        path = snapshot_mod.write_snapshot(snap, snap_dir)
        t_write = time.perf_counter() - t0
        assert path is not None
        import os
        snap_bytes = os.path.getsize(path)
        pool = eng.pool
        page_nbytes = (pool.k.nbytes + pool.v.nbytes) // pool.n_pages
        resident_kv_bytes = pool.used_pages() * page_nbytes
        imaged_pages = len(snap.pool["data_pages"])

        # Requests accepted AFTER the checkpoint: their only recovery
        # story is the write-ahead journal.  Drive until they are bound
        # (journaled), then die abruptly — no final snapshot, the worst
        # case a crash can present.
        handles += _submit(sessions, workload[n_requests:])
        late_ids = {h.req_id for h in handles[n_requests:]}
        ticks = 0
        while not late_ids <= {r["req_id"]
                               for r in eng._journal.records}:
            ticks += 1
            assert ticks < MAX_TICKS, "late binds never happened"
            eng.tick()

        # Kill: clients keep what their rings already committed.
        for s in sessions:
            s.pump()

        # Restore on a fresh engine; measure restore and the full
        # restore-to-first-token path (ticks until a client sees a new
        # token on its stream ring).
        eng2 = _mk_engine(model, params, n_requests,
                          snapshot_dir=snap_dir)
        _share_jit(eng2, ref_eng)
        t0 = time.perf_counter()
        report = eng2.restore_latest()
        t_restore = time.perf_counter() - t0
        assert report is not None, "no usable snapshot"
        sessions = [eng2.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        streamed_before = {
            h.req_id: len(h._tokens) for h in handles if not h.done}
        t0 = time.perf_counter()
        t_first_token = None
        ticks = 0
        while not all(h.test() for h in handles):
            ticks += 1
            assert ticks < MAX_TICKS, "restored engine wedged"
            eng2.tick()
            if t_first_token is None:
                for s in sessions:
                    s.pump()
                if any(len(h._tokens) > streamed_before.get(h.req_id, 0)
                       for h in handles if h.req_id in streamed_before):
                    t_first_token = time.perf_counter() - t0
        if t_first_token is None:       # everything finished pre-kill
            t_first_token = 0.0

        tokens = [list(map(int, h.response.tokens_out)) for h in handles]
        assert tokens == ref_tokens, \
            "restored streams diverged from the uninterrupted reference"
        # Tokens owed purely to the WAL: requests whose bind postdates
        # the snapshot's high-water mark and that no snapshot image
        # carried (slots / parked / deferred / queued).
        imaged = ({img.request.req_id for img in snap.slots}
                  | {p.req.req_id for p in snap.parked}
                  | {r.req_id for r, _ in snap.deferred}
                  | {r.req_id for r in snap.queued})
        replay_ids = {r["req_id"]
                      for r in eng._journal.records[snap.journal_seq:]
                      } - imaged
        replayed_tokens = sum(len(t) for h, t in zip(handles, tokens)
                              if h.req_id in replay_ids)
        assert report["replayed"] == len(replay_ids), \
            "journal replay count disagrees with the WAL delta"

        out = {
            "workload": {"n_requests": n_requests,
                         "max_tokens": max_tokens, "arch": args.arch,
                         "kill_at_tick": kill_at,
                         "reference_ticks": ref_ticks},
            "snapshot": {
                "capture_s": t_capture,
                "write_s": t_write,
                "bytes": snap_bytes,
                "resident_kv_bytes": resident_kv_bytes,
                "bytes_per_resident_kv_byte":
                    snap_bytes / max(resident_kv_bytes, 1),
                "imaged_pages": imaged_pages,
                "used_pages": pool.used_pages(),
            },
            "restore": {
                "restore_s": t_restore,
                "first_token_s": t_first_token,
                "resumed_requests": report["resumed"],
                "replayed_requests": report["replayed"],
                "redelivered_terminals": report["redelivered"],
                "replayed_tokens": replayed_tokens,
            },
            "byte_identical": True,
        }
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)

        print(f"snapshot: capture {t_capture * 1e3:.1f}ms + write "
              f"{t_write * 1e3:.1f}ms, {snap_bytes / 1024:.0f}KiB "
              f"({out['snapshot']['bytes_per_resident_kv_byte']:.2f}x "
              f"resident KV, {imaged_pages}/{pool.used_pages()} pages "
              f"imaged)")
        print(f"restore: {t_restore * 1e3:.1f}ms, first token "
              f"{t_first_token * 1e3:.1f}ms after; "
              f"{report['resumed']} resumed, {report['replayed']} "
              f"replayed, {report['redelivered']} redelivered — "
              f"byte-identical")
        print(f"-> {args.out}")
        return out
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
