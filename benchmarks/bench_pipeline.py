"""Device-level analogue of the paper's lock vs lock-free measurement.

The host benchmark (bench_lockfree) measures mutex vs NBB rings between
threads.  On TPU the same contrast is *barrier-style global exchange*
(all-gather the world every tick — the reference MCAPI global lock) vs
the NBB point-to-point ring (collective_permute).  We compile both
schedules for an 8-stage pipeline and compare:

  * collective bytes in the optimized HLO (the paper's "bus demand"),
  * wall time per microbatch on 8 host devices (CPU stand-in; the HLO
    byte ratio is hardware-independent and is what transfers to TPU).

Runs in a subprocess because it needs 8 forced host devices.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, re, time
import jax, jax.numpy as jnp
from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((8,), ("stage",))
S, M, B, D = 8, 16, 8, 256

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

params = {"w": jax.random.normal(jax.random.PRNGKey(0), (S, D, D),
                                 jnp.float32) * 0.1}
mbs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D), jnp.float32)

out = {}
for schedule in ("barrier", "nbb", "nbb2"):
    f = jax.jit(lambda p, m, s=schedule: pipeline_apply(
        stage_fn, p, m, mesh, axis="stage", schedule=s))
    lowered = f.lower(params, mbs)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    coll = 0
    for line in hlo.splitlines():
        mm = re.search(r"=\s+f32\[([\d,]+)\]\S*\s+(all-gather|"
                       r"collective-permute|all-reduce)\(", line)
        if mm:
            n = 1
            for d in mm.group(1).split(","):
                n *= int(d)
            coll += 4 * n
    r = f(params, mbs); jax.block_until_ready(r)   # warm
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        r = f(params, mbs)
    jax.block_until_ready(r)
    dt = (time.perf_counter() - t0) / reps
    out[schedule] = {"collective_bytes": coll,
                     "us_per_microbatch": dt / M * 1e6}
print(json.dumps(out))
"""


def run() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _WORKER],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main():
    out = run()
    print("schedule,collective_bytes,us_per_microbatch")
    for k, v in out.items():
        print(f"{k},{v['collective_bytes']},{v['us_per_microbatch']:.1f}")
    ratio = out["barrier"]["collective_bytes"] / max(
        out["nbb"]["collective_bytes"], 1)
    print(f"barrier_vs_nbb_bytes_ratio,{ratio:.1f}")
    return out


if __name__ == "__main__":
    main()
