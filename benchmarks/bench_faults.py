"""Fault-matrix benchmark: seeded FaultPlan sweep over the self-healing
serve loop (DESIGN.md §13).

The robustness analogue of ``bench_overload.py``: instead of offered
load exceeding capacity, the adversary is a deterministic
:class:`~repro.core.faults.FaultPlan` armed at a different injection
site per plan — transport refusals, a producer dying mid-span
reservation, pool claim/extend/CoW/swap failures, poisoned page writes,
dispatch raises, sync timeouts, and (ISSUE 9) torn snapshot writes,
aborted restores, and lost journal appends.  A no-fault baseline
records every request's token stream; then ``--plans`` seeded plans
(default 50, the ISSUE 8 acceptance sweep) each run the SAME workload
on a fresh engine (compiled traces shared from the baseline, so the
sweep compiles once).  Every sweep plan also crosses a kill-and-restore
boundary mid-run: the engine is abandoned, and a fresh engine resumes
from the newest good snapshot + write-ahead journal replay — recovery
itself runs under fire, and a fault *during* snapshot write must never
corrupt the last good snapshot (asserted).

Deterministic gates (asserted, every plan):
- the engine never deadlocks (a tick budget bounds each plan) and never
  raises out of ``tick()`` — the watchdog converts faults into typed
  ``FailedStatus`` terminals;
- every request reaches a terminal state: served + rejected + cancelled
  + shed + failed covers the workload (nothing stranded);
- surviving (COMPLETED) requests' tokens are byte-identical to the
  no-fault run — recovery may drop requests, never corrupt them;
- crash-consistent rollback: after drain, every pool page is free or
  quarantined, no sequence survives, and
  ``kv_copy_bytes == cow_copy_bytes + swap_in_bytes + swap_out_bytes``;
- across the sweep, every fault-site CLASS in the catalog fired at
  least once (the sweep actually exercised transport, pool, and engine).

Also measured (recorded, not asserted): the disarmed-plan overhead —
wall-clock of the baseline engine (no plan) vs an engine with an armed
plan whose rules never match, supporting the zero-overhead-when-quiet
claim.

Usage:  PYTHONPATH=src python benchmarks/bench_faults.py [--quick]
Emits:  BENCH_faults.json (cwd)
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from typing import Dict, List, Optional

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.core import faults  # noqa: E402
from repro.core.faults import FaultPlan, FaultRule  # noqa: E402
from repro.serve import snapshot as snapshot_mod  # noqa: E402
from repro.serve.overload import (  # noqa: E402
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    OverloadPolicy,
)

MAX_TICKS = 3000        # per plan: the no-deadlock gate


def make_workload(n_requests: int, seed: int = 0) -> List[Dict]:
    """Mixed-priority workload (deterministic).  The priority mix plus a
    deliberately tight pool force the preemption paths — swap_out /
    swap_in sites only fire if the scheduler actually tries to swap."""
    rng = np.random.default_rng(seed)
    work = []
    for _ in range(n_requests):
        u = rng.random()
        pri = (PRIORITY_HIGH if u < 0.25
               else PRIORITY_NORMAL if u < 0.7 else PRIORITY_LOW)
        work.append({
            "prompt": rng.integers(0, 1000, 8),
            "max_tokens": (4 if pri == PRIORITY_HIGH
                           else 8 if pri == PRIORITY_NORMAL else 24),
            "priority": pri,
        })
    return work


def _mk_engine(model, params, workload, fault_plan: Optional[FaultPlan],
               lease_s: Optional[float] = None,
               snapshot_dir: Optional[str] = None):
    from repro.serve.engine import ServeEngine

    # Tight pool (half the dense budget) so admission pressure is real
    # and the preempt/swap sites are reachable.
    max_batch, max_len, page_size = 2, 64, 8
    pool_pages = (max_batch * max_len + page_size - 1) // page_size // 2
    return ServeEngine(model, params, max_batch=max_batch, max_len=max_len,
                       n_clients=2, pool_pages=pool_pages,
                       page_size=page_size,
                       intake_depth=len(workload) + 8,
                       scheduler="slot_paged", chunk_tokens=16, k_max=4,
                       overload=OverloadPolicy(priorities=True,
                                               preemption=True),
                       fault_plan=fault_plan, lease_s=lease_s,
                       tick_retries=1, snapshot_dir=snapshot_dir,
                       snapshot_every=4 if snapshot_dir else None)


def _share_jit(eng, donor) -> None:
    """Adopt the donor's compiled-function caches (identical shapes):
    the 50-engine sweep then compiles each trace exactly once."""
    eng._jit_loops = donor._jit_loops
    eng._jit_chunked = donor._jit_chunked
    eng._jit_prefill = donor._jit_prefill
    eng._jit_decode = donor._jit_decode
    eng._jit_write_slot = donor._jit_write_slot
    eng.pool._cow_fns = donor.pool._cow_fns
    eng.pool._swap_fns = donor.pool._swap_fns


def run_plan(model, params, workload, plan: Optional[FaultPlan],
             donor=None, kill_at: Optional[int] = None) -> Dict:
    """One engine, one plan, the whole workload.  Returns per-request
    terminal states + tokens, the engine's fault report, and the engine
    itself (``"_eng"``, so the baseline can donate its compiled traces).
    Raises AssertionError on any invariant violation — CI fails on the
    first plan that breaks crash consistency.

    ``kill_at`` arms the ISSUE-9 kill-and-restore phase: after that
    many ticks the engine is abandoned mid-run (a final snapshot
    attempt first — which an injected ``snapshot.write`` fault may
    tear), clients drain what their rings already committed, and a
    FRESH engine restores from the newest good snapshot + journal
    replay, re-binds the live handles, and finishes the workload.  The
    torn write must never cost the previous good snapshot (asserted)."""
    snap_dir = (tempfile.mkdtemp(prefix="bench_faults_snap_")
                if kill_at is not None else None)
    try:
        eng = _mk_engine(model, params, workload, plan,
                         snapshot_dir=snap_dir)
        if donor is not None:
            _share_jit(eng, donor)
        sessions = [eng.connect(c) for c in range(2)]
        handles = [sessions[i % 2].submit_i(
                       w["prompt"] % model.cfg.vocab_size,
                       max_tokens=w["max_tokens"], priority=w["priority"])
                   for i, w in enumerate(workload)]

        t0 = time.monotonic()
        ticks = 0
        killed = False
        while not all(h.test() for h in handles):
            ticks += 1
            assert ticks < MAX_TICKS, (
                f"DEADLOCK: {sum(h.test() for h in handles)}/"
                f"{len(handles)} terminal after {MAX_TICKS} ticks "
                f"(plan={plan!r})")
            eng.tick()      # watchdog contract: this must never raise
            if (kill_at is not None and not killed and ticks >= kill_at
                    and eng.dead is None):
                killed = True
                _, last_good = snapshot_mod.load_latest(snap_dir)
                eng.save_snapshot()     # may be torn by snapshot.write
                if last_good is not None:
                    # A fault DURING snapshot write must never corrupt
                    # the previously-good snapshot: the loader still
                    # finds a valid one to fall back to.
                    _, now_good = snapshot_mod.load_latest(snap_dir)
                    assert now_good is not None, (
                        f"torn write lost the last-good snapshot "
                        f"under {plan!r}")
                for s in sessions:      # clients outlive the process:
                    s.pump()            # committed rings are theirs
                eng = _mk_engine(model, params, workload, plan,
                                 snapshot_dir=snap_dir)
                _share_jit(eng, donor if donor is not None else eng)
                eng.restore_latest()    # None => no good snapshot ever:
                sessions = [            # handles fail typed at re-bind
                    eng.connect(c, resume=s)
                    for c, s in enumerate(sessions)]
        dt = time.monotonic() - t0

        assert eng.dead is None, f"engine died under {plan!r}: {eng.dead}"

        # Crash-consistent rollback: pool exactly at its quiescent state.
        pool = eng.pool
        if eng.prefix_cache is not None:
            eng.prefix_cache.clear()
        assert pool.n_seqs() == 0, f"leaked sequences under {plan!r}"
        assert pool.used_pages() == len(pool.quarantined), \
            f"leaked pages under {plan!r}: {pool.stats()}"
        assert pool.kv_copy_bytes == (pool.cow_copy_bytes
                                      + pool.swap_in_bytes
                                      + pool.swap_out_bytes), \
            f"unattributed kv copy traffic under {plan!r}"

        s = eng.stats
        if not killed:
            # Stats-based coverage only holds single-life: a restored
            # engine's counters date from the snapshot, so requests
            # retired in the lost window between snapshot and kill are
            # counted by neither life (their HANDLES still resolved —
            # the per-handle terminal check below is the real gate).
            terminal = (s["served"] + s["rejected"] + s["cancelled"]
                        + s["shed_requests"] + s["requests_failed"])
            assert terminal >= len(workload), \
                f"stranded requests under {plan!r}: {s}"

        states_out, tokens_out = [], []
        for h in handles:
            r = h.response
            states_out.append(r.fsm.state.split("_")[-1])
            tokens_out.append(list(map(int, r.tokens_out))
                              if r.tokens_out is not None else [])
        report = eng.fault_report() if plan is not None else {}
        return {
            "wall_s": dt, "ticks": ticks, "states": states_out,
            "tokens": tokens_out, "report": report,
            "preemptions": s["preemptions"],
            "quarantined": len(pool.quarantined),
            "killed": killed,
            "restores": s["restores"],
            "replayed": s["replayed_requests"],
            "_eng": eng,
        }
    finally:
        if snap_dir is not None:
            shutil.rmtree(snap_dir, ignore_errors=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke (still 50 plans)")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--plans", type=int, default=50)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.models.model import build_model

    n_requests = args.requests or (6 if args.quick else 12)
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = make_workload(n_requests)

    # Baseline: no plan armed.  Its engine donates compiled traces to
    # every sweep engine, and its tokens are the byte-identity reference.
    cold = run_plan(model, params, workload, None)
    donor = cold["_eng"]
    # re-run trace-warm for an honest wall-clock number
    warm = run_plan(model, params, workload, None, donor=donor)
    assert all(st == "COMPLETED" for st in warm["states"]), \
        "no-fault baseline must complete every request"
    ref_tokens = warm["tokens"]

    # Disarmed-plan overhead: an armed plan whose rules never match.
    quiet = FaultPlan([FaultRule("nosuch.site", nth=1)])
    quiet_run = run_plan(model, params, workload, quiet, donor=donor)
    assert quiet_run["tokens"] == ref_tokens
    assert quiet_run["report"]["faults_injected"] == 0

    print(f"baseline: {n_requests} requests in {warm['wall_s']:.2f}s "
          f"({warm['ticks']} ticks); quiet-plan overhead "
          f"{quiet_run['wall_s'] / max(warm['wall_s'], 1e-9):.2f}x")

    # No-fault kill-and-restore: the engine is abandoned mid-run and a
    # fresh one resumes from snapshot + journal.  Every stream must come
    # out byte-identical to the uninterrupted baseline (ISSUE 9 gate).
    kill_at = 6
    recovery = run_plan(model, params, workload, None, donor=donor,
                        kill_at=kill_at)
    assert recovery["killed"], "kill-and-restore phase never armed"
    assert recovery["tokens"] == ref_tokens, \
        "restored streams diverged from the uninterrupted baseline"
    print(f"kill@{kill_at}+restore: byte-identical, "
          f"{recovery['replayed']} journal-replayed")

    # The acceptance sweep — every plan now ALSO crosses a kill-restore
    # boundary, so the snapshot/journal fault sites are reachable and
    # recovery itself runs under fire.
    hit_sites: set = set()
    survived = failed = identical = 0
    restores_total = replayed_total = 0
    per_plan = []
    for i, plan in enumerate(FaultPlan.sweep(args.plans, seed=args.seed)):
        r = run_plan(model, params, workload, plan, donor=donor,
                     kill_at=kill_at)
        hit_sites.update(r["report"].get("fired_sites", []))
        restores_total += r["restores"]
        replayed_total += r["replayed"]
        ok = True
        for st, toks, ref in zip(r["states"], r["tokens"], ref_tokens):
            if st == "COMPLETED":
                survived += 1
                assert toks == ref, (
                    f"plan {i} corrupted a SURVIVING request "
                    f"({plan!r}): {toks} != {ref}")
                identical += 1
            else:
                failed += 1
                ok = ok and st == "CANCELLED"
        assert ok, f"plan {i}: non-terminal state in {r['states']}"
        per_plan.append({
            "plan": i,
            "rules": [f"{ru.site}@{ru.nth}x{ru.times}"
                      for ru in plan.rules],
            "fired": r["report"].get("faults_injected", 0),
            "failed": r["report"].get("requests_failed", 0),
            "quarantined": r["quarantined"],
            "ticks": r["ticks"],
            "restores": r["restores"],
            "replayed": r["replayed"],
        })

    classes_hit = {s.split(".")[0] for s in hit_sites}
    classes_all = {s.split(".")[0] for s in faults.SITES}
    assert classes_hit == classes_all, \
        f"sweep missed site classes: {classes_all - classes_hit}"

    out = {
        "workload": {"n_requests": n_requests, "plans": args.plans,
                     "seed": args.seed, "arch": args.arch},
        "baseline_wall_s": warm["wall_s"],
        "quiet_plan_wall_s": quiet_run["wall_s"],
        "kill_restore": {
            "kill_at": kill_at,
            "byte_identical": True,
            "replayed_requests": recovery["replayed"],
        },
        "sweep": {
            "requests_total": args.plans * n_requests,
            "survived": survived,
            "failed": failed,
            "survivors_byte_identical": identical == survived,
            "site_classes_hit": sorted(classes_hit),
            "sites_hit": sorted(hit_sites),
            "restores": restores_total,
            "replayed_requests": replayed_total,
            "deadlocks": 0,
            "engine_deaths": 0,
        },
        "plans": per_plan,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)

    print(f"sweep: {args.plans} plans x {n_requests} requests "
          f"(kill@{kill_at}+restore each) -> {survived} survived "
          f"(all byte-identical), {failed} failed with typed terminals, "
          f"{restores_total} restores, 0 deadlocks, 0 engine deaths")
    print(f"sites hit: {sorted(hit_sites)}")
    print(f"-> {args.out}")
    return out


if __name__ == "__main__":
    main()
