"""Perf hillclimb driver (§Perf of EXPERIMENTS.md).

Each named variant = (arch, shape, rules_override, remat).  Runs the
dry-run cell, saves a tagged JSON next to the baseline, and prints the
three roofline terms + deltas vs baseline, so every hypothesis ->
change -> measure iteration is one command:

    PYTHONPATH=src python -m benchmarks.hillclimb smollm_dp
    PYTHONPATH=src python -m benchmarks.hillclimb --list
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"

# variant -> (arch, shape, rules_override, remat)
VARIANTS = {
    # --- iteration "full-length loss": model.loss now forwards all T
    #     tokens (rolled labels) instead of T-1, restoring power-of-two
    #     blocking. These re-measure the three cells with ONLY that fix.
    "smollm_fullloss": ("smollm-135m", "train_4k", None, "nothing"),
    "gemma3_fullloss": ("gemma3-27b", "train_4k", None, "nothing"),
    "arctic_fullloss": ("arctic-480b", "train_4k", None, "nothing"),
    # --- smollm-135m x train_4k: useful=0.03, model axis wasted (9 heads
    #     and d_ff 1536 divide 16 poorly) -> go pure 256-way DP.
    "smollm_dp": ("smollm-135m", "train_4k",
                  {"batch": ("pod", "data", "model"), "heads": None,
                   "kv_heads": None, "mlp": None, "vocab": None,
                   "cache_seq": None}, "nothing"),
    "smollm_dp_dots": ("smollm-135m", "train_4k",
                       {"batch": ("pod", "data", "model"), "heads": None,
                        "kv_heads": None, "mlp": None, "vocab": None,
                        "cache_seq": None}, "dots"),
    "smollm_seqp": ("smollm-135m", "train_4k",
                    {"seq": "model", "heads": None, "kv_heads": None,
                     "mlp": None, "vocab": None}, "nothing"),
    # 256-way DP activations + vocab-sharded embed table: kills the
    # replicated-table gradient scatter loop found by the op profile
    "smollm_dp_vocab": ("smollm-135m", "train_4k",
                        {"batch": ("pod", "data", "model"), "heads": None,
                         "kv_heads": None, "mlp": None,
                         "cache_seq": None}, "nothing"),
    # --- gemma3-27b x train_4k: collective-bound (917 GB all-reduce/dev).
    #     Megatron SP: shard the residual stream's seq dim over model so
    #     per-block sync becomes reduce-scatter/all-gather pairs.
    "gemma3_sp": ("gemma3-27b", "train_4k", {"seq": "model"}, "nothing"),
    "gemma3_dots": ("gemma3-27b", "train_4k", None, "dots"),
    "gemma3_sp_dots": ("gemma3-27b", "train_4k", {"seq": "model"}, "dots"),
    # --- arctic-480b x train_4k: memory-bound, 164 GB/dev (doesn't fit).
    "arctic_sp": ("arctic-480b", "train_4k", {"seq": "model"}, "nothing"),
    "arctic_dots": ("arctic-480b", "train_4k", None, "dots"),
    "arctic_sp_dots": ("arctic-480b", "train_4k", {"seq": "model"}, "dots"),
    # 5-tuples: last element = gradient-accumulation microbatches
    "arctic_sp_mb4": ("arctic-480b", "train_4k", {"seq": "model"},
                      "nothing", 4),
    "arctic_sp_dots_mb8": ("arctic-480b", "train_4k", {"seq": "model"},
                           "dots", 8),
    "gemma3_sp_dots_mb4": ("gemma3-27b", "train_4k", {"seq": "model"},
                           "dots", 4),
    # zamba2: head-sharded SSD recurrence sends GSPMD into windowed
    # einsum loops (3140 s memory term); replicate heads / shard seq
    "zamba_noheads": ("zamba2-2.7b", "train_4k", {"heads": None},
                      "nothing"),
    "zamba_sp": ("zamba2-2.7b", "train_4k",
                 {"heads": None, "seq": "model"}, "nothing"),
    "gemma3_sp_mb4": ("gemma3-27b", "train_4k", {"seq": "model"},
                      "nothing", 4),
    "smollm_dp_mb4": ("smollm-135m", "train_4k",
                      {"batch": ("pod", "data", "model"), "heads": None,
                       "kv_heads": None, "mlp": None, "vocab": None,
                       "cache_seq": None}, "nothing", 4),
}


def run_variant(name: str, multi_pod: bool = False):
    # deferred: sets XLA_FLAGS for 512 host devices on import
    from repro.launch import dryrun
    from benchmarks.roofline import analyze_record

    spec = VARIANTS[name]
    arch, shape, rules, remat = spec[:4]
    mb = spec[4] if len(spec) > 4 else 1
    rec = dryrun.dryrun_cell(arch, shape, multi_pod, remat=remat,
                             rules_override=rules, microbatches=mb)
    rec["variant"] = name
    dryrun.save(rec, tag=f"__opt_{name}")

    base_p = RESULTS / f"{arch}__{shape}__{rec['mesh']}.json"
    base = analyze_record(json.loads(base_p.read_text()))
    opt = analyze_record(rec)
    print(f"\n=== {name}: {arch} x {shape} (remat={remat}) ===")
    print(f"{'term':14}{'baseline':>12}{'variant':>12}{'delta':>9}")
    for t in ("compute_s", "memory_s", "collective_s"):
        b, o = base[t], opt[t]
        print(f"{t:14}{b:12.3e}{o:12.3e}{(o / b - 1) * 100:8.0f}%")
    for t in ("mfu_bound", "useful_ratio", "peak_gb"):
        print(f"{t:14}{base[t]:12.3f}{opt[t]:12.3f}")
    return rec


def main():
    args = sys.argv[1:]
    if not args or args[0] == "--list":
        for k, v in VARIANTS.items():
            print(f"{k}: {v[0]} x {v[1]} rules={v[2]} remat={v[3]}")
        return
    for name in args:
        run_variant(name)


if __name__ == "__main__":
    main()
