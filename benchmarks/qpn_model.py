"""Queueing model of the shared-memory bottleneck (paper §5, Figure 6).

The paper models the memory bus as the single queueing resource for the
lock-free exchange: tasks issue memory operations; cache hits bypass the
bus.  We reproduce the model analytically (M/M/1-style open network —
the QPN's single queue) and then apply the *same methodology* to the TPU
(three resources: MXU FLOPs, HBM, ICI), which is exactly the roofline of
benchmarks/roofline.py — the paper's "model as stop criterion" mapped to
hardware we target.

Model parameters (from the paper's setup):
  * ops_per_msg   — memory operations to send+receive one message
                    (counted from the UML sequence diagrams; paper
                    implies ~tens; we default 40).
  * t_mem         — main-memory access time (~65 ns, public benchmarks
                    [35] for the Westmere-era parts in §4).
  * hit_rate      — probability an op is served by cache (no bus demand).
  * cores         — concurrent senders (the paper plots 1 and 2).

Outputs reproduce Figure 6's shapes: bus utilization rises with cores and
falls with hit rate; throughput saturates once the bus does.  The
theoretical max msgs/s at hit=1.0 bounds ~what the paper quotes
(~630k msgs/s => 0.63 us per message service time at their constants).
"""
from __future__ import annotations

from typing import Dict, List


def simulate(target_rate_msgs_s: float = 630_000.0,
             ops_per_msg: int = 40, t_mem_ns: float = 65.0,
             cores: int = 1, hit_rate: float = 0.9) -> Dict:
    """Closed-form open-network solution for one hit-rate point.

    Offered load: each core offers ``target_rate / cores`` msgs/s (the
    workload is fixed, split across cores); each message demands
    ``ops_per_msg * (1 - hit_rate)`` bus operations of ``t_mem`` each.
    The bus serves at most 1/t_mem ops/s; throughput is capped by bus
    saturation, and per-core issue capacity caps a single core below the
    target (the paper's "a single core cannot saturate the bus").
    """
    t_mem_s = t_mem_ns * 1e-9
    bus_ops_per_s = 1.0 / t_mem_s
    miss_ops_per_msg = ops_per_msg * (1.0 - hit_rate)

    # Per-core issue rate limit: a core must *execute* all ops_per_msg
    # operations (hits cost ~1/10 t_mem in L1/L2, misses cost t_mem).
    t_hit_s = t_mem_s / 10.0
    t_msg_core = ops_per_msg * (hit_rate * t_hit_s
                                + (1.0 - hit_rate) * t_mem_s)
    core_capacity = cores / t_msg_core                    # msgs/s

    # Bus capacity in msgs/s (infinite when every op hits).
    bus_capacity = (bus_ops_per_s / miss_ops_per_msg
                    if miss_ops_per_msg > 0 else float("inf"))

    throughput = min(target_rate_msgs_s, core_capacity, bus_capacity)
    utilization = (throughput * miss_ops_per_msg) / bus_ops_per_s
    return {
        "cores": cores, "hit_rate": hit_rate,
        "throughput_msgs_s": throughput,
        "throughput_pct_of_target": 100.0 * throughput / target_rate_msgs_s,
        "bus_utilization_pct": 100.0 * utilization,
        "bottleneck": ("bus" if throughput == bus_capacity else
                       "core" if throughput == core_capacity else "none"),
    }


def figure6(hit_rates=None, cores=(1, 2)) -> List[Dict]:
    hit_rates = hit_rates or [i / 20 for i in range(10, 21)]  # 0.5..1.0
    return [simulate(cores=c, hit_rate=h) for c in cores for h in hit_rates]


def theoretical_max(ops_per_msg: int = 40, t_mem_ns: float = 65.0,
                    hit_rate: float = 0.9) -> float:
    """Messages/s when only cache+memory transactions are counted (the
    paper's 630k msgs/s, i.e. 0.63 us per message, with its constants)."""
    t_mem_s = t_mem_ns * 1e-9
    t_hit_s = t_mem_s / 10.0
    t_msg = ops_per_msg * (hit_rate * t_hit_s + (1 - hit_rate) * t_mem_s)
    return 1.0 / t_msg


def main():
    print("cores,hit_rate,throughput_msgs_s,throughput_pct,bus_util_pct,"
          "bottleneck")
    for r in figure6():
        print(f"{r['cores']},{r['hit_rate']:.2f},"
              f"{r['throughput_msgs_s']:.0f},"
              f"{r['throughput_pct_of_target']:.1f},"
              f"{r['bus_utilization_pct']:.1f},{r['bottleneck']}")
    tm = theoretical_max()
    print(f"\ntheoretical_max_msgs_s,{tm:.0f}")
    print(f"us_per_msg,{1e6 / tm:.2f}")
    return figure6(), tm


if __name__ == "__main__":
    main()
