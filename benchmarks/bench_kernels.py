"""Kernel micro-bench: Pallas (interpret on CPU) vs jnp reference.

On this CPU container interpret-mode wall time is meaningless; what we
record per kernel is (a) allclose vs the oracle at bench shapes, and
(b) the analytic VMEM working set + arithmetic intensity per BlockSpec
tile — the numbers that determine TPU performance (DESIGN.md §Perf
hints).  Wall time of the *reference* path is also printed as the CPU
sanity anchor.

The paged-attention row additionally records the copy traffic the
block-table kernel DELETES: ``swap_bytes_deleted`` is what a dense
gather swap-in would move per decode batch versus the int32 block-table
row that paged residency writes instead (DESIGN.md §10).

Usage: PYTHONPATH=src python benchmarks/bench_kernels.py [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nbb_matmul import nbb_matmul
from repro.kernels.paged_attention import paged_attention


def _time(f, *args, reps=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def paged_attention_report(quick: bool = False):
    """Decode-shape paged attention: block-table kernel vs the dense
    gather it replaces (the reference IS the gather path)."""
    B, T, H, Hkv, hd = (2, 1, 4, 2, 64) if quick else (4, 1, 8, 2, 128)
    ps, P = 16, (4 if quick else 16)
    n_pages = 4 * B * P
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, hd)), jnp.float32)
    block = jnp.asarray(rng.permutation(n_pages)[:B * P].reshape(B, P),
                        jnp.int32)
    lens = jnp.asarray(rng.integers(T, P * ps, size=(B,)), jnp.int32)
    out = paged_attention(q, kp, vp, block, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, block, lens)
    err = float(jnp.abs(out - want).max())
    # per-grid-step VMEM: q tile + one (k, v) page pair + f32 scratch
    vmem = (T * hd + 2 * ps * hd) * 4 + (T * hd + 2 * T) * 4
    flops_tile = 2 * 2 * T * ps * hd               # qk^T + pv
    bytes_tile = (2 * ps * hd) * 4                 # k,v page per step
    t_ref = _time(lambda a, b, c: ref.paged_attention_ref(a, b, c, block,
                                                          lens), q, kp, vp)
    # What residency costs: a gather swap-in moves every live page of
    # the batch; the block table is B rows of P int32s.
    swap_bytes = int((jnp.ceil(lens / ps)).sum()) * ps * Hkv * hd * 4 * 2
    return {"kernel": "paged_attention", "max_err": err, "tol": 2e-5,
            "vmem_tile_kb": vmem / 1024,
            "arith_intensity": flops_tile / bytes_tile,
            "ref_cpu_ms": t_ref * 1e3,
            "swap_bytes_deleted": swap_bytes,
            "block_table_bytes": int(block.size) * 4}


def flash_attention_report(quick: bool = False):
    B, T, H, hd = (1, 256, 4, 128) if quick else (1, 1024, 4, 128)
    bq = bk = 128
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    err = float(jnp.abs(out - want).max())
    # per-tile VMEM: q + k + v tiles + fp32 scratch (acc, m, l)
    vmem = (bq * hd + 2 * bk * hd) * 4 + (bq * hd + 2 * bq) * 4
    flops_tile = 2 * 2 * bq * bk * hd              # qk^T + pv
    bytes_tile = (bk * hd * 2) * 4                 # k,v stream per step
    t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c), q, k, v)
    return {"kernel": "flash_attention", "max_err": err, "tol": 2e-5,
            "vmem_tile_kb": vmem / 1024,
            "arith_intensity": flops_tile / bytes_tile,
            "ref_cpu_ms": t_ref * 1e3}


def nbb_matmul_report(quick: bool = False):
    M = N = 256 if quick else 512
    K = 512 if quick else 1024
    bm = bn = 256
    bk = 512
    a = jax.random.normal(jax.random.PRNGKey(3), (M, K), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(4), (K, N), jnp.bfloat16)
    out = nbb_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_ref(a, b)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    # 2-slot rings: 2*(bm*bk + bk*bn) operand tiles + fp32 acc
    vmem = 2 * (bm * bk + bk * bn) * 2 + bm * bn * 4
    flops_tile = 2 * bm * bn * bk
    bytes_tile = (bm * bk + bk * bn) * 2
    t_ref = _time(lambda x, y: ref.matmul_ref(x, y), a, b)
    # bf16 operands with split-K accumulation (default shapes: K=1024 in
    # bk=512 steps): the achievable agreement is bf16-ulp scale, not f32.
    return {"kernel": "nbb_matmul", "max_err": err, "tol": 0.5,
            "vmem_tile_kb": vmem / 1024,
            "arith_intensity": flops_tile / bytes_tile,
            "ref_cpu_ms": t_ref * 1e3}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    args = ap.parse_args(argv)
    print("kernel,max_err,vmem_tile_kb,arith_intensity,ref_cpu_ms")
    rows = [flash_attention_report(args.quick),
            nbb_matmul_report(args.quick),
            paged_attention_report(args.quick)]
    for r in rows:
        print(f"{r['kernel']},{r['max_err']:.2e},{r['vmem_tile_kb']:.0f},"
              f"{r['arith_intensity']:.0f},{r['ref_cpu_ms']:.1f}")
        assert r["max_err"] < r["tol"], f"{r['kernel']} diverged from oracle"
        assert r["vmem_tile_kb"] < 16 * 1024, "tile exceeds 16 MB VMEM"
    pa = rows[-1]
    print(f"paged residency: block table {pa['block_table_bytes']} B "
          f"replaces a {pa['swap_bytes_deleted'] / 1024:.0f} KiB "
          f"gather swap-in per decode batch")
    return rows


if __name__ == "__main__":
    main()
