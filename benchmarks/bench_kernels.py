"""Kernel micro-bench: Pallas (interpret on CPU) vs jnp reference.

On this CPU container interpret-mode wall time is meaningless; what we
record per kernel is (a) allclose vs the oracle at bench shapes, and
(b) the analytic VMEM working set + arithmetic intensity per BlockSpec
tile — the numbers that determine TPU performance (DESIGN.md §Perf
hints).  Wall time of the *reference* path is also printed as the CPU
sanity anchor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nbb_matmul import nbb_matmul


def _time(f, *args, reps=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def flash_attention_report():
    B, T, H, hd = 1, 1024, 4, 128
    bq = bk = 128
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    err = float(jnp.abs(out - want).max())
    # per-tile VMEM: q + k + v tiles + fp32 scratch (acc, m, l)
    vmem = (bq * hd + 2 * bk * hd) * 4 + (bq * hd + 2 * bq) * 4
    flops_tile = 2 * 2 * bq * bk * hd              # qk^T + pv
    bytes_tile = (bk * hd * 2) * 4                 # k,v stream per step
    t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c), q, k, v)
    return {"kernel": "flash_attention", "max_err": err,
            "vmem_tile_kb": vmem / 1024,
            "arith_intensity": flops_tile / bytes_tile,
            "ref_cpu_ms": t_ref * 1e3}


def nbb_matmul_report():
    M = N = 512
    K = 1024
    bm = bn = 256
    bk = 512
    a = jax.random.normal(jax.random.PRNGKey(3), (M, K), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(4), (K, N), jnp.bfloat16)
    out = nbb_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_ref(a, b)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    # 2-slot rings: 2*(bm*bk + bk*bn) operand tiles + fp32 acc
    vmem = 2 * (bm * bk + bk * bn) * 2 + bm * bn * 4
    flops_tile = 2 * bm * bn * bk
    bytes_tile = (bm * bk + bk * bn) * 2
    t_ref = _time(lambda x, y: ref.matmul_ref(x, y), a, b)
    return {"kernel": "nbb_matmul", "max_err": err,
            "vmem_tile_kb": vmem / 1024,
            "arith_intensity": flops_tile / bytes_tile,
            "ref_cpu_ms": t_ref * 1e3}


def main():
    print("kernel,max_err,vmem_tile_kb,arith_intensity,ref_cpu_ms")
    rows = [flash_attention_report(), nbb_matmul_report()]
    for r in rows:
        print(f"{r['kernel']},{r['max_err']:.2e},{r['vmem_tile_kb']:.0f},"
              f"{r['arith_intensity']:.0f},{r['ref_cpu_ms']:.1f}")
        assert r["vmem_tile_kb"] < 16 * 1024, "tile exceeds 16 MB VMEM"
    return rows


if __name__ == "__main__":
    main()
