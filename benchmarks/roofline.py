"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads every results/dryrun/*.json produced by repro.launch.dryrun and
derives, per (arch x shape x mesh):

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs            (197 TF/s bf16)
  memory_s     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
  collective_s = collective_bytes_per_device / ICI_link_bw    (50 GB/s/link)

plus MODEL_FLOPS (6*N_active*D train / 2*N_active*D inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and the
roofline fraction = max-term / sum-of-terms-if-serial... we report
`bound_s = max(terms)` (perfectly-overlapped lower bound) and
`frac = compute_s / bound_s` (how compute-bound the cell is; 1.0 means
MXU-limited — the best place to be).

This file IS the paper's QPN model methodology (§5) re-targeted: one
queueing resource per hardware bottleneck, service demand from static
analysis of the compiled program, the resulting cap used as the stop
criterion for refactoring (§Perf iterations stop when the dominant term
stops moving).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"

# shape -> (tokens per step, is_train)
_SHAPE_TOKENS = {
    "train_4k": (4096 * 256, True),
    "prefill_32k": (32768 * 32, False),
    "decode_32k": (128, False),        # one new token x batch 128
    "long_500k": (1, False),           # one new token x batch 1
}


def model_flops(rec: Dict) -> float:
    tokens, is_train = _SHAPE_TOKENS[rec["shape"]]
    n = rec["active_param_count"]
    per_tok = 6.0 * n if is_train else 2.0 * n
    return per_tok * tokens / rec["n_devices"]


def analyze_record(rec: Dict) -> Dict:
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = rec["bytes_accessed_per_device"] / HBM_BW
    coll = sum(rec["collective_bytes_per_device"].values()) / ICI_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant,
        "bound_s": bound,
        "roofline_frac": comp / bound if bound else 0.0,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / rec["flops_per_device"]
        if rec["flops_per_device"] else 0.0,
        "mfu_bound": mf / (bound * PEAK_FLOPS) if bound else 0.0,
        "peak_gb": rec["memory"]["peak_estimate_bytes"] / 1e9,
    }


def load_all(mesh: Optional[str] = "16x16", tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted(RESULTS.glob(f"*{tag}.json")):
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        if tag and not p.stem.endswith(tag):
            continue
        if not tag and "__opt" in p.stem:
            continue
        rows.append(analyze_record(rec))
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | MFU-bound | useful | peak GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['mfu_bound']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['peak_gb']:.1f} |")
    return "\n".join(lines)


def main():
    rows = load_all()
    print(fmt_table(rows))
    by_dom = {}
    for r in rows:
        by_dom.setdefault(r["dominant"], []).append(r)
    print(f"\n# {len(rows)} cells; dominant-term census: "
          + ", ".join(f"{k}={len(v)}" for k, v in sorted(by_dom.items())))
    worst = sorted(rows, key=lambda r: r["mfu_bound"])[:3]
    print("# worst MFU-bound cells: "
          + ", ".join(f"{r['arch']}x{r['shape']}({r['mfu_bound']:.2f})"
                      for r in worst))
    coll = sorted(rows, key=lambda r: -r["collective_s"])[:3]
    print("# most collective-bound: "
          + ", ".join(f"{r['arch']}x{r['shape']}({r['collective_s']:.1e}s)"
                      for r in coll))
    return rows


if __name__ == "__main__":
    main()
