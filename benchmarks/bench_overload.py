"""Overload-control benchmark: FIFO intake vs the priority/preemption/
WFQ subsystem under open-loop saturation (DESIGN.md §12).

The paper's stop criterion frames every run as "offered load the system
must absorb"; this benchmark makes the offered load *exceed* capacity —
the regime the overload subsystem exists for.  It first measures the
engine's closed-loop capacity on a mixed-priority workload, then
replays the same workload OPEN-LOOP at 2x that rate (arrivals keep
coming whether or not the engine kept up) through two engines:

- **fifo**: ``overload=None`` — the seed's single MPSC intake.  Priority
  tags ride along but mean nothing; a high-priority request queues
  behind every earlier long low-priority generation.
- **overload**: ``OverloadPolicy(priorities, preemption, wfq)`` — the
  multi-class intake pops high first (with aging so low never starves),
  and a high-priority arrival under slot/pool pressure swaps a running
  low-priority slot's private pages to host (``BUFFER_PREEMPTED``),
  resuming it byte-identically once pressure clears.

Deterministic gates (asserted):
- token streams per request are byte-identical fifo vs overload — the
  scheduler may only reorder and swap, never change a single token;
- ``kv_copy_bytes == cow_copy_bytes + swap_in_bytes + swap_out_bytes``
  — every copied KV byte is attributable to CoW or preemption swaps;
- no starvation: every low-priority request completes in both runs.

Headline (recorded, wall-clock so not asserted): high-priority TTFT
p50/p99 ratio overload/fifo — the ISSUE target is p99 <= 0.5x — plus
preemption/resume counts, swap traffic, and a shed demonstration pass
(tight SLO at the same offered load -> typed ``ShedStatus`` rejects).

Usage:  PYTHONPATH=src python benchmarks/bench_overload.py [--quick]
Emits:  BENCH_overload.json (cwd)
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.serve.overload import (      # noqa: E402
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    OverloadPolicy,
)

CLASS_NAMES = {PRIORITY_HIGH: "high", PRIORITY_NORMAL: "normal",
               PRIORITY_LOW: "low"}


def make_workload(n_requests: int, seed: int = 0) -> List[Dict]:
    """Mixed-priority workload, deterministic: ~20% high / 60% normal /
    20% low.  High requests are short interactive turns (the ones whose
    TTFT the subsystem protects); low requests are long generations —
    exactly the slots worth preempting when a high arrives under
    pressure."""
    rng = np.random.default_rng(seed)
    work = []
    for _ in range(n_requests):
        u = rng.random()
        pri = (PRIORITY_HIGH if u < 0.2
               else PRIORITY_NORMAL if u < 0.7 else PRIORITY_LOW)
        # Low generations span many fused blocks (40 tokens vs k_max=4),
        # so under saturation both slots are typically pinned by cheap
        # long work when a high arrives — the case where intake priority
        # alone cannot help and only page-swap preemption can.
        work.append({
            "prompt": rng.integers(0, 1000, 8),
            "max_tokens": (6 if pri == PRIORITY_HIGH
                           else 12 if pri == PRIORITY_NORMAL else 40),
            "priority": pri,
        })
    return work


def _pct(vals: List[float], q: float) -> float:
    if not vals:
        return float("nan")
    s = sorted(vals)
    return s[min(int(len(s) * q), len(s) - 1)]


def _mk_engine(model, params, workload: List[Dict],
               overload: Optional[OverloadPolicy], max_batch: int,
               max_len: int):
    from repro.serve.engine import ServeEngine

    # The pool IS the device KV store (slot_paged): size it to the dense
    # batch-cache budget so saturation pressure is real, not synthetic.
    page_size = 8
    pool_pages = (max_batch * max_len + page_size - 1) // page_size
    return ServeEngine(model, params, max_batch=max_batch, max_len=max_len,
                       n_clients=1, pool_pages=pool_pages,
                       page_size=page_size,
                       intake_depth=len(workload) + 8,
                       scheduler="slot_paged", chunk_tokens=16, k_max=4,
                       overload=overload)


def run_pass(model, params, workload: List[Dict],
             overload: Optional[OverloadPolicy], max_batch: int,
             max_len: int, arrivals: Optional[List[float]] = None) -> Dict:
    """One engine, one pass.  ``arrivals=None`` -> closed loop (submit
    everything up front; measures capacity).  Otherwise open loop:
    request i is submitted no earlier than ``arrivals[i]`` seconds after
    t0, while the engine steps — lag never cancels future arrivals."""
    eng = _mk_engine(model, params, workload, overload, max_batch, max_len)

    def terminal() -> int:
        return (eng.stats["served"] + eng.stats["rejected"]
                + eng.stats["cancelled"] + eng.stats["shed_requests"])

    # Warmup: trace prefill/decode shapes outside the timed region.
    for w in workload[:2]:
        eng.submit(0, w["prompt"] % model.cfg.vocab_size,
                   max_tokens=w["max_tokens"])
    while terminal() < 2:
        eng.step()
    for _ in range(2):
        assert eng.get_response(0, timeout_s=10), "warmup timed out"

    for k in eng.stats:
        eng.stats[k] = 0
    eng.pool.reset_traffic()
    eng._ttft_by_class.clear()

    # Drive per-TICK, not per-step(): step() drains the whole backlog
    # before returning, which would serialize the open loop — arrivals
    # must land BETWEEN fused blocks, while slots are still held.
    t0 = time.monotonic()
    rids: List[int] = []
    nxt = 0
    while nxt < len(workload) or terminal() < len(workload):
        while nxt < len(workload) and (
                arrivals is None
                or time.monotonic() - t0 >= arrivals[nxt]):
            w = workload[nxt]
            req = eng.submit(0, w["prompt"] % model.cfg.vocab_size,
                             max_tokens=w["max_tokens"],
                             priority=w["priority"])
            assert req is not None, "intake ring full mid-benchmark"
            rids.append(req.req_id)
            nxt += 1
        eng.tick()
    dt = time.monotonic() - t0

    seqs: Dict[int, List[int]] = {}
    ttft_by_class: Dict[int, List[float]] = {}
    done_by_class: Dict[int, List[float]] = {}
    served_by_class: Dict[int, int] = {}
    shed = 0
    for _ in range(len(workload)):
        r = eng.get_response(0, timeout_s=10)
        assert r, "response timed out"
        seqs[r.req_id] = (list(map(int, r.tokens_out))
                          if r.tokens_out is not None else [])
        if r.status is not None and not r.status:
            shed += 1
            continue
        served_by_class[r.priority] = served_by_class.get(r.priority, 0) + 1
        ttft_by_class.setdefault(r.priority, []).append(
            1e3 * ((r.first_token_t or r.done_t) - r.submit_t))
        done_by_class.setdefault(r.priority, []).append(
            1e3 * (r.done_t - r.submit_t))

    pstats = eng.pool.stats()
    return {
        "mode": "fifo" if overload is None else "overload",
        "wall_s": dt,
        "req_per_s": len(workload) / dt,
        "served": eng.stats["served"],
        "shed": shed,
        "preemptions": eng.stats["preemptions"],
        "resumes": eng.stats["resumes"],
        "shed_requests": eng.stats["shed_requests"],
        "swap_in_bytes": pstats["swap_in_bytes"],
        "swap_out_bytes": pstats["swap_out_bytes"],
        "kv_copy_bytes": pstats["kv_copy_bytes"],
        "cow_copy_bytes": pstats["cow_copy_bytes"],
        "ttft_ms": {CLASS_NAMES[c]: {"n": len(v),
                                     "p50": _pct(v, 0.5),
                                     "p99": _pct(v, 0.99)}
                    for c, v in sorted(ttft_by_class.items())},
        "completion_ms": {CLASS_NAMES[c]: {"p50": _pct(v, 0.5),
                                           "p99": _pct(v, 0.99)}
                          for c, v in sorted(done_by_class.items())},
        "served_by_class": {CLASS_NAMES[c]: n
                            for c, n in sorted(served_by_class.items())},
        "_token_seqs": [seqs[r] for r in rids],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--overload-factor", type=float, default=2.0,
                    help="open-loop offered load as a multiple of "
                         "measured closed-loop capacity")
    ap.add_argument("--out", default="BENCH_overload.json")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.models.model import build_model

    n_requests = args.requests or (16 if args.quick else 40)
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = make_workload(n_requests)
    mix = {CLASS_NAMES[c]: sum(1 for w in workload if w["priority"] == c)
           for c in (PRIORITY_HIGH, PRIORITY_NORMAL, PRIORITY_LOW)}
    kw = dict(max_batch=args.max_batch, max_len=64)

    # Capacity calibration: closed loop, FIFO — the service rate the
    # open-loop passes will oversubscribe.
    cal = run_pass(model, params, workload, None, **kw)
    cap = cal["req_per_s"]
    arrivals = [i / (args.overload_factor * cap)
                for i in range(len(workload))]
    print(f"capacity {cap:.1f} req/s -> offered "
          f"{args.overload_factor * cap:.1f} req/s "
          f"({args.overload_factor:.0f}x, {n_requests} requests, "
          f"mix {mix})")

    fifo = run_pass(model, params, workload, None, arrivals=arrivals, **kw)
    policy = OverloadPolicy(priorities=True, preemption=True, wfq=True)
    over = run_pass(model, params, workload, policy, arrivals=arrivals,
                    **kw)

    # Gate 1: the scheduler may reorder and swap, never change tokens.
    assert fifo["_token_seqs"] == over["_token_seqs"], \
        "overload control changed tokens (preempt/resume not transparent)"
    # Gate 2: every copied KV byte is attributable (CoW or swap).
    for r in (fifo, over):
        assert r["kv_copy_bytes"] == (r["cow_copy_bytes"]
                                      + r["swap_in_bytes"]
                                      + r["swap_out_bytes"]), \
            f"unattributed kv copy traffic in {r['mode']} pass"
    assert fifo["preemptions"] == 0 and fifo["swap_out_bytes"] == 0
    # Gate 3: no starvation — aging must get every low-priority request
    # through despite strict priority under 2x load.
    for r in (fifo, over):
        assert r["served"] == n_requests, f"{r['mode']}: lost requests"
        assert r["served_by_class"].get("low", 0) == mix["low"], \
            f"{r['mode']}: low-priority starvation"

    # Shed demonstration: same offered load, 25 ms admission SLO -> the
    # backlog ages out as typed ShedStatus rejects instead of queueing.
    shed_policy = OverloadPolicy(priorities=True, preemption=True,
                                 wfq=True, slo_s=0.025)
    shed = run_pass(model, params, workload, shed_policy,
                    arrivals=arrivals, **kw)
    assert shed["shed_requests"] == shed["shed"], \
        "engine shed counter disagrees with delivered ShedStatus count"

    hi_f, hi_o = fifo["ttft_ms"].get("high"), over["ttft_ms"].get("high")
    ratio = {q: (hi_o[q] / hi_f[q] if hi_f and hi_o and hi_f[q] > 0
                 else float("nan")) for q in ("p50", "p99")}
    out = {
        "workload": {"n_requests": n_requests, "mix": mix,
                     "max_batch": args.max_batch,
                     "overload_factor": args.overload_factor,
                     "capacity_req_per_s": cap, "arch": args.arch},
        "fifo": fifo, "overload": over, "shed_slo_25ms": shed,
        "high_ttft_ratio_overload_vs_fifo": ratio,
        "tokens_identical": True,
        "kv_copy_fully_attributed": True,
        "low_priority_starved": False,
    }
    for r in (cal, fifo, over, shed):
        r.pop("_token_seqs", None)      # identity already asserted
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)

    for r in (fifo, over):
        hi = r["ttft_ms"].get("high", {"p50": float("nan"),
                                       "p99": float("nan")})
        lo = r["completion_ms"].get("low", {"p99": float("nan")})
        print(f"{r['mode']:8s}: high ttft p50 {hi['p50']:.0f} "
              f"p99 {hi['p99']:.0f} ms  low done p99 {lo['p99']:.0f} ms  "
              f"preempt {r['preemptions']}  resume {r['resumes']}  "
              f"swap {(r['swap_in_bytes'] + r['swap_out_bytes']) // 1024}"
              f"KiB")
    print(f"shed pass: {shed['shed_requests']} shed / "
          f"{n_requests} offered (25 ms SLO)")
    print(f"high-priority ttft overload/fifo: p50 {ratio['p50']:.2f}x  "
          f"p99 {ratio['p99']:.2f}x  (target <= 0.5x)  -> {args.out}")
    return out


if __name__ == "__main__":
    main()
